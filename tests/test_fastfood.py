"""The Fastfood fast path (ISSUE 8): FWHT kernel Pallas-vs-XLA agreement
across non-power-of-two d (the padding path), int8 structured artifacts
(layout, >= 3x serialization win, argmax parity, digest determinism,
pad-head neutrality), the fwht tuning families surviving table
validation, and the structured roofline prior that lets compile_model
rank Fastfood against dense RFF."""

import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import backend, gamma_max
from repro.core.families import fourier, quantize
from repro.core.rbf import SVMModel
from repro.kernels.common import tuning
from repro.kernels.common.config import TileConfig
from repro.kernels.fwht import (
    fastfood_project,
    fastfood_score_pallas,
    fastfood_score_q8_pallas,
    fastfood_score_q8_ref,
    fastfood_score_ref,
    fwht,
    fwht_xla,
)
from repro.launch import roofline
from repro.serve.svm_engine import SVMEngine


def _svm_mc(seed=0, d=8, n_sv=40, k=4, scale=0.5):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n_sv, d)).astype(np.float32) * scale
    gamma = float(gamma_max(jnp.asarray(X))) * 0.8
    ay = rng.standard_normal((k, n_sv)).astype(np.float32) * 0.5
    b = (rng.standard_normal(k) * 0.1).astype(np.float32)
    return SVMModel(X=jnp.asarray(X), alpha_y=jnp.asarray(ay),
                    b=jnp.asarray(b), gamma=jnp.float32(gamma))


def _operands(rng, n, d, stacks, k):
    """Random Fastfood operands at d' = next pow2 >= d."""
    dd = 1 << max(1, (d - 1).bit_length())
    f = stacks * dd
    return dict(
        Z=jnp.asarray(rng.standard_normal((n, d)).astype(np.float32)),
        B=jnp.asarray(rng.choice(np.float32([-1, 1]), (stacks, dd))),
        G=jnp.asarray(rng.standard_normal((stacks, dd)).astype(np.float32)),
        perm=jnp.asarray(
            np.stack([rng.permutation(dd) for _ in range(stacks)]).astype(np.int32)
        ),
        scale=jnp.asarray(
            (rng.standard_normal((stacks, dd)) * 0.1).astype(np.float32)
        ),
        phase=jnp.asarray(rng.uniform(0, 2 * np.pi, f).astype(np.float32)),
        weights=jnp.asarray(
            (rng.standard_normal((k, f)) * 0.05).astype(np.float32)
        ),
        bias=jnp.asarray(rng.standard_normal(k).astype(np.float32)),
    )


# ----------------------------------------------------------- transform math


def test_fwht_matches_hadamard_matrix():
    # Sylvester construction is the ground truth for the butterfly loop.
    d = 16
    H = np.array([[1.0]])
    while H.shape[0] < d:
        H = np.block([[H, H], [H, -H]])
    x = np.random.default_rng(0).standard_normal((5, d)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(fwht(jnp.asarray(x))), x @ H.T, rtol=1e-5, atol=1e-4
    )


@pytest.mark.parametrize("d", [1, 2, 8, 64, 1024])
def test_fwht_xla_matches_butterfly(d):
    # The Kronecker-GEMM schedule (what fastfood_project dispatches under
    # XLA) must agree with the butterfly (what the Pallas kernel unrolls)
    # at every width class: trivial, odd-k (unbalanced split), balanced.
    x = np.random.default_rng(d).standard_normal((7, d)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(fwht_xla(jnp.asarray(x))), np.asarray(fwht(jnp.asarray(x))),
        rtol=1e-5, atol=1e-4,
    )


def test_fastfood_project_pads_nonpow2_d_exactly():
    # Zero-padding d -> d' must equal projecting the explicitly padded Z.
    rng = np.random.default_rng(1)
    ops = _operands(rng, 7, 20, 2, 3)
    dd = ops["B"].shape[1]
    Zp = jnp.pad(ops["Z"], ((0, 0), (0, dd - 20)))
    a = fastfood_project(ops["Z"], ops["B"], ops["G"], ops["perm"], ops["scale"])
    b = fastfood_project(Zp, ops["B"], ops["G"], ops["perm"], ops["scale"])
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------ Pallas-vs-XLA parity


@pytest.mark.parametrize("d", [6, 20, 100])
def test_fastfood_pallas_matches_xla_nonpow2_d(d):
    rng = np.random.default_rng(d)
    ops = _operands(rng, 33, d, 3, 5)  # n=33: exercises row-tile padding
    ref = fastfood_score_ref(**ops)
    got = fastfood_score_pallas(
        **ops, config=TileConfig(block_n=16), interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-4
    )


@pytest.mark.parametrize("d", [6, 20, 100])
def test_fastfood_q8_pallas_matches_xla_nonpow2_d(d):
    rng = np.random.default_rng(100 + d)
    ops = _operands(rng, 21, d, 2, 6)
    stacks, k = 2, 6
    q = dict(
        Z=ops["Z"],
        b_q=ops["B"].astype(jnp.int8),
        g_q=jnp.clip(jnp.round(ops["G"] / 0.02), -127, 127).astype(jnp.int8),
        perm=ops["perm"],
        s_q=jnp.clip(jnp.round(ops["scale"] / 0.002), -127, 127).astype(jnp.int8),
        stack_scale=jnp.full((stacks,), 0.02 * 0.002, jnp.float32),
        phase=ops["phase"],
        weights_q=jnp.clip(
            jnp.round(ops["weights"] / 0.001), -127, 127
        ).astype(jnp.int8),
        wt_scale=jnp.full((k,), 0.001, jnp.float32),
        bias=ops["bias"],
    )
    ref = fastfood_score_q8_ref(**q)
    got = fastfood_score_q8_pallas(
        **q, config=TileConfig(block_n=8), interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-4
    )


def test_backend_dispatch_agrees_across_backends():
    rng = np.random.default_rng(5)
    ops = _operands(rng, 17, 20, 2, 4)
    prev = backend.set_backend("xla")
    try:
        sx = backend.fastfood_score(**ops)
        backend.set_backend("pallas")
        sp = backend.fastfood_score(**ops)
    finally:
        backend.set_backend(prev)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sx), atol=1e-4)


# -------------------------------------------------------- int8 artifacts


def test_int8_fastfood_artifact_contract():
    m = _svm_mc(7, d=100, n_sv=60, k=10)
    f32 = fourier.compile(m, num_features=2048, structured=True, seed=3)
    q8 = fourier.compile(
        m, num_features=2048, structured=True, dtype="int8", seed=3
    )
    # layout: every F- or K-scaling array narrowed
    a = q8.arrays
    assert a["ff_b"].dtype == jnp.int8 and a["ff_g"].dtype == jnp.int8
    assert a["ff_scale"].dtype == jnp.int8
    assert a["ff_perm"].dtype == jnp.int16
    assert a["phase"].dtype == jnp.float16
    assert a["weights"].dtype == jnp.int8
    # >= 3x smaller serialized (ISSUE 8 acceptance)
    ratio = len(f32.to_bytes()) / len(q8.to_bytes())
    assert ratio >= 3.0, ratio
    # measured quant error rides in the meta
    assert q8.meta["quant_mean_abs_err"] < 0.05
    assert q8.meta["quant_holdout_n"] > 0
    # argmax parity vs the f32 parent on held-out points
    Z = jnp.asarray(fourier.holdout_sample(m, 3, 128))
    s32, _ = fourier.score(f32, Z)
    s8, _ = fourier.score(q8, Z)
    parity = float(np.mean(
        np.argmax(np.asarray(s32), 1) == np.argmax(np.asarray(s8), 1)
    ))
    assert parity >= 0.99, parity
    # distinct content addresses, both serve through the engine
    assert f32.digest() != q8.digest()
    labels = SVMEngine(q8, allow_fallback=False).predict_labels(
        np.asarray(Z[:9])
    )
    assert labels.shape == (9,)


def test_int8_fastfood_digest_deterministic_in_process():
    m = _svm_mc(11, d=20, n_sv=40, k=3)
    d1 = fourier.compile(
        m, num_features=64, structured=True, dtype="int8", seed=5
    ).digest()
    d2 = fourier.compile(
        m, num_features=64, structured=True, dtype="int8", seed=5
    ).digest()
    assert d1 == d2


def test_quantize_signs_and_compact_perm():
    assert quantize.quantize_signs(
        jnp.asarray([[1.0, -1.0]])
    ).dtype == jnp.int8
    with pytest.raises(ValueError, match="sign"):
        quantize.quantize_signs(jnp.asarray([0.5, 1.0]))
    assert quantize.compact_perm(np.arange(64)).dtype == jnp.int16
    assert quantize.compact_perm(np.arange(2**16)).dtype == jnp.int32


@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_fastfood_pad_heads_is_argmax_neutral(dtype):
    m = _svm_mc(13, d=20, n_sv=40, k=5)
    art = fourier.compile(
        m, num_features=64, structured=True, dtype=dtype, seed=2
    )
    padded = fourier.pad_heads(art, 4)
    assert padded.meta["padded_heads"] == 8
    Z = jnp.asarray(fourier.holdout_sample(m, 2, 32))
    ref, _ = fourier.score(art, Z)
    got, _ = fourier.score(padded, Z)
    np.testing.assert_allclose(
        np.asarray(got[:, :5]), np.asarray(ref), rtol=1e-5, atol=1e-5
    )
    assert int(np.asarray(got).argmax(axis=1).max()) < 5
    # aligned width is a no-op
    assert fourier.pad_heads(art, 5) is art


# ----------------------------------------------------------- tuning registry


def test_tile_lookup_resolves_fwht_families():
    m = _svm_mc(17, d=20, k=3)
    f32 = fourier.compile(m, num_features=64, structured=True)
    q8 = fourier.compile(m, num_features=64, structured=True, dtype="int8")
    kf, key = fourier.tile_lookup(f32, 256)
    kq, _ = fourier.tile_lookup(q8, 256)
    assert kf == "fwht" and kq == "fwht_q8"
    assert key == tuning.shape_key(d=20, f=64, n=256)
    # both families resolve a default config without raising
    assert tuning.lookup(kf, key).block_n > 0
    assert tuning.lookup(kq, key).block_n > 0


def test_validate_table_drops_unknown_kernel_keeps_fwht():
    # Regression (ISSUE 8 satellite): a table shipped by a NEWER build with
    # kernel families this build doesn't know must warn-and-drop those
    # entries, not break the loader — and the fwht entries this PR ships
    # must survive validation in the current build.
    entry = {"config": {"block_n": 128}, "measured_ms": 0.5}
    table = {
        "version": 1,
        "entries": {"cpu": {
            "fwht": {"d784_f2048_n256": entry},
            "fwht_q8": {"d784_f2048_n256": entry},
            "kernel_from_the_future": {"d8_n32": entry},
        }},
    }
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        clean = tuning.validate_table(table, origin="test")
    assert any("kernel_from_the_future" in str(x.message) for x in w)
    kept = clean["entries"]["cpu"]
    assert set(kept) == {"fwht", "fwht_q8"}
    assert kept["fwht"]["d784_f2048_n256"] == entry
    # the original table is not mutated
    assert "kernel_from_the_future" in table["entries"]["cpu"]


# ------------------------------------------------------------ roofline prior


def test_roofline_structured_prior_undercuts_dense_at_mnist_shape():
    cfg = TileConfig(block_n=256)
    dense = roofline.rff_tile_seconds(cfg, n=256, d=784, f=2048, k=10)
    structured = roofline.fwht_tile_seconds(cfg, n=256, d=784, f=2048, k=10)
    assert structured < dense
    # int8 streams fewer bytes than f32 in both forms
    assert roofline.fwht_tile_seconds(
        cfg, n=256, d=784, f=2048, k=10, weight_bytes=1
    ) <= structured
    # family_candidate_seconds threads structured= through
    fd = roofline.family_candidate_seconds(
        "fourier", "float32", n=256, d=784, k=10, num_features=2048
    )
    fs = roofline.family_candidate_seconds(
        "fourier", "float32", n=256, d=784, k=10, num_features=2048,
        structured=True,
    )
    assert fs < fd
    # bigger tiles amortize the streamed readout
    assert roofline.fwht_tile_seconds(
        TileConfig(block_n=512), n=1024, d=784, f=2048, k=10
    ) < roofline.fwht_tile_seconds(
        TileConfig(block_n=64), n=1024, d=784, f=2048, k=10
    )
