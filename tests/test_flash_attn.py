"""Fused flash-attention kernel vs the softmax oracle (shape/dtype sweep)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attn import flash_attention, softmax_attention_ref


@pytest.mark.parametrize("B,H,T,D,DV,bq,bk", [
    (1, 1, 64, 16, 16, 16, 16),
    (2, 3, 128, 32, 32, 32, 64),
    (1, 2, 100, 16, 16, 32, 32),   # T not divisible by blocks -> padding
    (2, 1, 96, 24, 48, 32, 32),    # dv != d
    (1, 1, 256, 64, 64, 256, 64),  # single q block, multi kv
])
def test_flash_matches_softmax(B, H, T, D, DV, bq, bk):
    rng = np.random.default_rng(B * T + D)
    q = jnp.asarray(rng.standard_normal((B, H, T, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, H, T, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, H, T, DV)).astype(np.float32))
    ref = softmax_attention_ref(q, k, v)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_flash_online_softmax_stability():
    """Large logits: the online max-shift must prevent overflow."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 1, 64, 16)).astype(np.float32)) * 30
    k = jnp.asarray(rng.standard_normal((1, 1, 64, 16)).astype(np.float32)) * 30
    v = jnp.asarray(rng.standard_normal((1, 1, 64, 16)).astype(np.float32))
    out = flash_attention(q, k, v, block_q=16, block_k=16)
    ref = softmax_attention_ref(q, k, v)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3, atol=1e-3)


def test_flash_impl_in_model_matches_blockwise():
    """cfg.attention_impl='flash' is a drop-in for the blockwise path."""
    import dataclasses
    import jax
    from repro.configs import ARCHS
    from repro.models.transformer import forward, init_params

    cfg = dataclasses.replace(ARCHS["qwen2-0.5b"].reduced(), dtype="float32")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
    l1, _ = forward(cfg, params, tokens)
    l2, _ = forward(dataclasses.replace(cfg, attention_impl="flash"), params, tokens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-3, atol=2e-3)
