"""Checkpoint/restart + fault-tolerance contract tests."""

import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.train import checkpoint as ckpt
from repro.data.loader import ShardedLoader


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layers": {"w": jnp.asarray(rng.standard_normal((4, 8)).astype(np.float32))},
        "head": (jnp.asarray(rng.standard_normal(3).astype(np.float32)),
                 jnp.float32(2.5)),
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t)
    assert ckpt.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), t)
    r = ckpt.restore(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_pointer_advances_atomically(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    ckpt.save(str(tmp_path), 2, jax.tree.map(lambda l: l + 1, t))
    assert ckpt.latest_step(str(tmp_path)) == 2
    # both checkpoints exist; older is restorable (crash-rollback path)
    r1 = ckpt.restore(str(tmp_path), 1, t)
    r2 = ckpt.restore(str(tmp_path), 2, t)
    np.testing.assert_allclose(
        np.asarray(r2["layers"]["w"]), np.asarray(r1["layers"]["w"]) + 1
    )


def test_async_checkpointer(tmp_path):
    t = _tree()
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    saver.save(11, t)
    saver.wait()
    assert saver.last_committed == 11
    assert ckpt.latest_step(str(tmp_path)) == 11


def test_restore_with_resharding(tmp_path):
    """Elastic-remesh path: restore device_puts onto provided shardings."""
    t = _tree()
    ckpt.save(str(tmp_path), 3, t)
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda l: jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()), t
    )
    r = ckpt.restore(str(tmp_path), 3, t, shardings=sh)
    assert all(x.sharding == s for x, s in zip(jax.tree.leaves(r), jax.tree.leaves(sh)))


def test_loader_is_step_resumable():
    """batch_at(step) is pure — a restart mid-epoch replays identically."""
    X = np.arange(1000, dtype=np.float32).reshape(100, 10)
    y = np.arange(100, dtype=np.float32)
    l1 = ShardedLoader(X, y, global_batch=8, seed=5, shard_index=1, num_shards=2)
    l2 = ShardedLoader(X, y, global_batch=8, seed=5, shard_index=1, num_shards=2)
    for step in (0, 17, 123):
        a, _ = l1.batch_at(step)
        b, _ = l2.batch_at(step)
        np.testing.assert_array_equal(a, b)
    # different shards see disjoint rows of the same global batch
    l0 = ShardedLoader(X, y, global_batch=8, seed=5, shard_index=0, num_shards=2)
    a0, _ = l0.batch_at(3)
    a1, _ = l1.batch_at(3)
    assert a0.shape == a1.shape == (4, 10)


def test_crash_safe_tmpdir_never_latest(tmp_path):
    """A simulated crash mid-save must not corrupt LATEST."""
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    # simulate a partial write: create step_2.tmp and 'crash'
    os.makedirs(tmp_path / "step_2.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 1  # still points to the good one
    r = ckpt.restore(str(tmp_path), 1, t)
    assert r is not None
