"""The frozen public surface: ``repro.serve.__all__``, the error
taxonomy's stable (code, http_status) table, and the ``PublishSpec``
unification contract. These are SNAPSHOT tests — a diff here means the
public API changed, which must be a deliberate, reviewed event, never
a side effect of a refactor."""

import dataclasses

import pytest

import repro.serve as serve
import repro.serve.runtime as runtime_pkg
from repro.serve.runtime import PublishSpec, errors
from repro.serve.runtime.publish import resolve_spec

# ------------------------------------------------------------- the snapshots

SERVE_ALL = [
    "ArtifactCorrupt",
    "ArtifactRegistry",
    "BatcherClosed",
    "CircuitBreaker",
    "DeadlineExceeded",
    "DriftGuard",
    "EngineResult",
    "EngineStats",
    "FaultInjector",
    "MicroBatcher",
    "ModelNotFound",
    "PublishSpec",
    "Runtime",
    "RuntimeOverloaded",
    "SVMEngine",
    "ServingError",
    "SliceResult",
    "bucket_size",
    "compile_model",
    "create_app",
    "make_prefill_step",
    "make_serve_step",
    "serve",
]

RUNTIME_ALL = [
    "ArtifactCorrupt",
    "ArtifactRegistry",
    "BatcherClosed",
    "CircuitBreaker",
    "DeadlineExceeded",
    "DriftGuard",
    "ENGINE_STEP",
    "FaultInjector",
    "InjectedFault",
    "LatencyWindow",
    "MetricsRegistry",
    "MicroBatcher",
    "ModelNotFound",
    "ModelTelemetry",
    "Observability",
    "PublishSpec",
    "REGISTRY_LOAD",
    "RegistryEntry",
    "ReservoirSampler",
    "Runtime",
    "RuntimeOverloaded",
    "ServingError",
    "Tracer",
    "render_prometheus",
]

# Every refusal a wire client can observe: (class name, code, status).
ERROR_TAXONOMY = [
    ("ArtifactCorrupt", "artifact_corrupt", 503),
    ("BatcherClosed", "batcher_closed", 503),
    ("DeadlineExceeded", "deadline_exceeded", 504),
    ("InjectedFault", "injected_fault", 500),
    ("ModelNotFound", "model_not_found", 404),
    ("RuntimeOverloaded", "overloaded", 429),
    ("ServingError", "serving_error", 500),
]


def test_serve_surface_is_frozen():
    assert sorted(serve.__all__) == SERVE_ALL
    for name in serve.__all__:
        assert getattr(serve, name, None) is not None, name


def test_runtime_surface_is_frozen():
    assert sorted(runtime_pkg.__all__) == RUNTIME_ALL
    for name in runtime_pkg.__all__:
        assert getattr(runtime_pkg, name, None) is not None, name


def test_error_codes_and_statuses_are_frozen():
    table = [
        (cls.__name__, cls.code, cls.http_status)
        for cls in vars(errors).values()
        if isinstance(cls, type) and issubclass(cls, errors.ServingError)
    ]
    assert sorted(table) == ERROR_TAXONOMY
    # codes are unique — a wire client switching on code is unambiguous
    codes = [code for _, code, _ in table]
    assert len(codes) == len(set(codes))


def test_errors_keep_their_pre_taxonomy_bases():
    # every pre-taxonomy `except` clause must keep catching
    assert issubclass(errors.RuntimeOverloaded, RuntimeError)
    assert issubclass(errors.DeadlineExceeded, TimeoutError)
    assert issubclass(errors.BatcherClosed, RuntimeError)
    assert issubclass(errors.ArtifactCorrupt, RuntimeError)
    assert issubclass(errors.ModelNotFound, KeyError)
    # and ModelNotFound messages read like messages, not quoted keys
    assert str(errors.ModelNotFound("no such model", ref="x")) == "no such model"


def test_error_to_wire_is_the_wire_body():
    e = errors.RuntimeOverloaded("queue full", retry_after_s=0.25)
    assert e.to_wire() == {
        "code": "overloaded", "status": 429, "message": "queue full",
        "retry_after_s": 0.25,
    }


# ----------------------------------------------------------- PublishSpec API


def test_publish_spec_wire_roundtrip():
    spec = PublishSpec(alias="det", replicas=2, warmup=True)
    assert spec.to_wire() == {"alias": "det", "replicas": 2, "warmup": True}
    assert PublishSpec.from_wire(spec.to_wire()) == spec


def test_publish_spec_exact_never_crosses_the_wire():
    spec = PublishSpec(exact=object())
    assert spec.to_wire() == {"has_exact": True}


def test_publish_spec_rejects_unknown_wire_fields():
    with pytest.raises(ValueError, match="unknown PublishSpec fields"):
        PublishSpec.from_wire({"replcas": 2})


def test_publish_spec_validates_replicas():
    with pytest.raises(ValueError):
        PublishSpec(replicas=0)


def test_publish_spec_is_frozen():
    with pytest.raises(dataclasses.FrozenInstanceError):
        PublishSpec().alias = "x"


def test_legacy_kwargs_fold_with_deprecation_warning():
    with pytest.warns(DeprecationWarning, match="Runtime.publish"):
        spec = resolve_spec(None, caller="Runtime.publish",
                            exact=None, replicas=3)
    assert spec == PublishSpec(replicas=3)


def test_spec_plus_legacy_kwargs_is_an_error():
    with pytest.raises(TypeError, match="not both"):
        resolve_spec(PublishSpec(), caller="x", replicas=2)
