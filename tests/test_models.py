"""Per-architecture smoke tests (deliverable f) + cross-path consistency:
decode-vs-forward equivalence for the stateful families, blockwise-vs-naive
attention, maclaurin backend parity."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models.attention import _gqa_scores_full
from repro.models.transformer import decode, forward, init_cache, init_params
from repro.models.ssm import (
    mamba2_decode,
    mamba2_forward,
    mamba2_init_state,
    mamba2_params,
)
from repro.models.rwkv import (
    channel_mix,
    rwkv6_init_state,
    rwkv6_params,
    time_mix_decode,
    time_mix_forward,
)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_forward_and_decode(name):
    """One fwd + one decode step on the reduced config; shapes + finiteness."""
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(0)
    params, spec = init_params(cfg, key)
    # spec tree mirrors params tree
    assert jax.tree.structure(jax.tree.map(lambda _: 0, params)) == jax.tree.structure(
        jax.tree.map(lambda _: 0, spec, is_leaf=lambda x: isinstance(x, tuple))
    )
    B, T = 2, 32
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    img = (
        jax.random.normal(key, (B, cfg.n_image_tokens, cfg.d_model))
        if cfg.family == "vlm" else None
    )
    logits, aux = forward(cfg, params, tokens, img)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    cache = init_cache(cfg, B, 64, image_embeds=img, params=params, dtype=jnp.float32)
    lg, cache2 = decode(cfg, params, tokens[:, :1], jnp.int32(0), cache, img)
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(lg)))
    # cache structure is preserved (required for jit donation)
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", ["smollm-135m", "qwen2-0.5b"])
def test_decode_matches_forward_dense(name):
    """Greedy per-token decode reproduces the teacher-forced forward logits."""
    cfg = dataclasses.replace(ARCHS[name].reduced(), dtype="float32")
    key = jax.random.PRNGKey(1)
    params, _ = init_params(cfg, key)
    B, T = 1, 8
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    full_logits, _ = forward(cfg, params, tokens)
    cache = init_cache(cfg, B, T, params=params, dtype=jnp.float32)
    outs = []
    for t in range(T):
        lg, cache = decode(cfg, params, tokens[:, t : t + 1], jnp.int32(t), cache)
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), rtol=2e-2, atol=2e-2
    )


def test_mamba2_decode_matches_forward():
    key = jax.random.PRNGKey(2)
    d, T, B = 64, 12, 2
    params, _ = mamba2_params(key, d, d_state=16, head_dim=32)
    x = jax.random.normal(key, (B, T, d)) * 0.5
    full = mamba2_forward(params, x, d_state=16, head_dim=32, chunk=4)
    state = mamba2_init_state(B, d, d_state=16, head_dim=32)
    outs = []
    for t in range(T):
        o, state = mamba2_decode(params, x[:, t : t + 1], state, d_state=16, head_dim=32)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full), rtol=2e-3, atol=2e-3)


def test_rwkv6_decode_matches_forward():
    key = jax.random.PRNGKey(3)
    d, T, B = 64, 8, 2
    params, _ = rwkv6_params(key, d, 128, head_dim=32)
    x = jax.random.normal(key, (B, T, d)) * 0.5
    full = time_mix_forward(params, x, head_dim=32, chunk=4)
    S, x_tm, _ = rwkv6_init_state(B, d, head_dim=32)
    outs = []
    st = (S, x_tm)
    for t in range(T):
        o, st = time_mix_decode(params, x[:, t : t + 1], st, head_dim=32)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full), rtol=2e-3, atol=2e-3)


def test_rwkv6_channel_mix_shift_consistency():
    key = jax.random.PRNGKey(4)
    d, T, B = 32, 6, 1
    params, _ = rwkv6_params(key, d, 64, head_dim=16)
    x = jax.random.normal(key, (B, T, d))
    full, _ = channel_mix(params, x)
    last = jnp.zeros((B, 1, d))
    outs = []
    for t in range(T):
        o, last = channel_mix(params, x[:, t : t + 1], last)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(full), rtol=1e-4, atol=1e-5
    )


def test_blockwise_attention_matches_naive():
    """The flash-style q-chunked attention == naive full-matrix softmax."""
    rng = np.random.default_rng(5)
    B, T, Hq, Hkv, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, T, Hq, hd)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, hd)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, hd)).astype(np.float32))

    def naive(q, k, v):
        g = Hq // Hkv
        qh = q.reshape(B, T, Hkv, g, hd)
        u = jnp.einsum("bthgd,bshd->bhgts", qh, k) / np.sqrt(hd)
        mask = jnp.tril(jnp.ones((T, T), dtype=bool))
        u = jnp.where(mask, u, -jnp.inf)
        w = jax.nn.softmax(u, axis=-1)
        return jnp.einsum("bhgts,bshd->bthgd", w, v).reshape(B, T, Hq, hd)

    blocked = _gqa_scores_full(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(naive(q, k, v)), rtol=2e-4, atol=2e-5)


def test_maclaurin_backend_decode_runs():
    """long_500k path: decode with the paper-technique state cache."""
    cfg = ARCHS["smollm-135m"].reduced().with_backend("maclaurin")
    key = jax.random.PRNGKey(6)
    params, _ = init_params(cfg, key)
    B = 2
    cache = init_cache(cfg, B, 1 << 19, params=params)  # S only bounds positions
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    lg, cache2 = decode(cfg, params, tok, jnp.int32(0), cache)
    assert bool(jnp.all(jnp.isfinite(lg)))
    # the state is context-length-free: identical leaf shapes regardless of S
    cache_small = init_cache(cfg, B, 128, params=params)
    assert jax.tree.map(lambda l: l.shape, cache2) == jax.tree.map(
        lambda l: l.shape, cache_small
    )


def test_param_counts_sane():
    """Analytic param counts should be within ~35% of the advertised sizes."""
    expect = {
        "smollm-135m": 135e6,
        "qwen2-0.5b": 500e6,
        "phi3-mini-3.8b": 3.8e9,
        "yi-34b": 34e9,
        "qwen3-moe-30b-a3b": 30e9,
    }
    for name, n in expect.items():
        got = ARCHS[name].param_count()
        assert 0.65 < got / n < 1.45, f"{name}: {got:.2e} vs {n:.2e}"
