"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret) vs jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.common import TileConfig
from repro.kernels.rbf_pred import rbf_predict, rbf_predict_ref
from repro.kernels.quadform import quadform_predict, quadform_predict_ref
from repro.kernels.maclaurin_attn import (
    maclaurin_attention,
    maclaurin_attention_ref,
    softmax_attention_ref,
    maclaurin_weights,
)
from repro.models.maclaurin_attention import (
    extend_state,
    init_state,
    maclaurin_attention_gqa,
    readout,
)


@pytest.mark.parametrize("n,m,d", [(7, 13, 3), (64, 128, 22), (33, 257, 100), (128, 64, 123)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_rbf_pred_shapes(n, m, d, dtype):
    rng = np.random.default_rng(n * m)
    Z = jnp.asarray(rng.standard_normal((n, d)).astype(dtype))
    X = jnp.asarray(rng.standard_normal((m, d)).astype(dtype))
    a = jnp.asarray(rng.standard_normal(m).astype(dtype))
    ref = rbf_predict_ref(Z, X, a, 0.05, -0.2)
    out = rbf_predict(Z, X, a, 0.05, -0.2, config=TileConfig(block_n=32, block_m=64))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,d", [(5, 4), (100, 22), (257, 123), (64, 780)])
def test_quadform_shapes(n, d):
    rng = np.random.default_rng(n * d)
    Z = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    M = rng.standard_normal((d, d)).astype(np.float32)
    M = jnp.asarray((M + M.T) / 2)
    v = jnp.asarray(rng.standard_normal(d).astype(np.float32))
    ref_f, ref_sq = quadform_predict_ref(Z, M, v, 0.7, -0.1, 0.02)
    out_f, out_sq = quadform_predict(Z, M, v, 0.7, -0.1, 0.02, config=TileConfig(block_n=64))
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(ref_f), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out_sq), np.asarray(ref_sq), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("B,H,T,D,DV,chunk", [
    (1, 1, 32, 8, 8, 8),
    (2, 3, 100, 16, 16, 32),   # T not divisible by chunk -> padding path
    (1, 2, 256, 32, 32, 128),
    (2, 1, 64, 24, 48, 16),    # d_v != d_k
])
def test_maclaurin_attn_kernel_vs_ref(B, H, T, D, DV, chunk):
    rng = np.random.default_rng(B * T + D)
    q = jnp.asarray(rng.standard_normal((B, H, T, D)).astype(np.float32)) * 0.3
    k = jnp.asarray(rng.standard_normal((B, H, T, D)).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.standard_normal((B, H, T, DV)).astype(np.float32))
    ref = maclaurin_attention_ref(q, k, v)
    out = maclaurin_attention(q, k, v, config=TileConfig(chunk=chunk))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_maclaurin_weights_positive():
    """w(u) = 1 + u + u^2/2 >= 1/2 — the normalizer can never vanish."""
    u = jnp.linspace(-100, 100, 10001)
    assert float(jnp.min(maclaurin_weights(u))) >= 0.5 - 1e-6


def test_maclaurin_attn_approximates_softmax_for_small_logits():
    """The paper's claim, transplanted: for |u| < 1/2 the attention weights
    are within ~3% of exp's, so outputs track softmax attention closely."""
    rng = np.random.default_rng(0)
    B, H, T, D = 1, 2, 64, 16
    # scale queries/keys so |q.k|/sqrt(D) stays < 1/2
    q = jnp.asarray(rng.standard_normal((B, H, T, D)).astype(np.float32)) * 0.35
    k = jnp.asarray(rng.standard_normal((B, H, T, D)).astype(np.float32)) * 0.35
    v = jnp.asarray(rng.standard_normal((B, H, T, D)).astype(np.float32))
    exact = softmax_attention_ref(q, k, v)
    approx = maclaurin_attention_ref(q, k, v)
    err = np.abs(np.asarray(exact - approx)) / (np.abs(np.asarray(exact)) + 1e-2)
    assert np.median(err) < 0.05


def test_state_decode_matches_full_attention():
    """Sequential extend_state+readout == full-sequence maclaurin attention
    (the O(d^2) decode state is exactly the collapsed predictor)."""
    rng = np.random.default_rng(1)
    B, Hkv, T, D = 2, 2, 24, 8
    g = 2
    q = jnp.asarray(rng.standard_normal((B, T, Hkv * g, D)).astype(np.float32)) * 0.4
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, D)).astype(np.float32)) * 0.4
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, D)).astype(np.float32))
    full = maclaurin_attention_gqa(q, k, v)                  # (B, T, Hq, D)

    state = init_state((B, Hkv), D, D)
    outs = []
    for t in range(T):
        kt = k[:, t : t + 1].transpose(0, 2, 1, 3)           # (B,Hkv,1,D)
        vt = v[:, t : t + 1].transpose(0, 2, 1, 3)
        state = extend_state(state, kt, vt)
        qt = q[:, t].reshape(B, Hkv, g, D)
        out, valid = readout(state, qt)
        outs.append(out.reshape(B, 1, Hkv * g, D))
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full), rtol=2e-3, atol=2e-4)


def test_readout_validity_flag():
    """The Eq 3.11 analogue flips when keys/queries leave the safe envelope."""
    B, Hkv, D = 1, 1, 8
    state = init_state((B, Hkv), D, D)
    small_k = 0.1 * jnp.ones((B, Hkv, 4, D))
    state = extend_state(state, small_k, small_k)
    q_small = 0.1 * jnp.ones((B, Hkv, 1, D))
    _, valid = readout(state, q_small)
    assert bool(jnp.all(valid))
    q_big = 100.0 * jnp.ones((B, Hkv, 1, D))
    _, valid2 = readout(state, q_big)
    assert not bool(jnp.any(valid2))
