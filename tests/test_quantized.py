"""Int8 artifact variants (ISSUE 5): round-trip bit-identity, digest
separation from the f32 parents, the >= 3x serialization win, argmax
parity through the engine, fused-dequant kernel agreement (pallas
interpret vs xla), registry eviction/reload of quantized entries, and
quantization as a first-class candidate axis in compile_model."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import Budget, CompiledArtifact, backend, compile_model, gamma_max
from repro.core.families import FAMILIES, get_family, quantize, score_artifact
from repro.core.families.base import ARTIFACT_FORMAT_VERSION
from repro.core.rbf import SVMModel
from repro.serve.svm_engine import SVMEngine

NUM_FEATURES = 256          # small fourier basis keeps the suite fast


def _svm(seed=0, d=8, n_sv=60, heads=None, scale=0.6):
    """Deterministic small model straight from an rng (no training)."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n_sv, d)).astype(np.float32) * scale
    gamma = float(gamma_max(jnp.asarray(X))) * 0.8
    if heads is None:
        ay = rng.standard_normal(n_sv).astype(np.float32) * 0.5
        b = jnp.float32(0.1)
    else:
        ay = rng.standard_normal((heads, n_sv)).astype(np.float32) * 0.5
        b = jnp.asarray(0.1 * rng.standard_normal(heads).astype(np.float32))
    return SVMModel(X=jnp.asarray(X), alpha_y=jnp.asarray(ay),
                    b=b, gamma=jnp.float32(gamma))


def _compile_pair(family, m, **opts):
    fam = get_family(family)
    f32 = fam.compile(m, num_features=NUM_FEATURES, **opts)
    q8 = fam.compile(m, num_features=NUM_FEATURES, dtype="int8", **opts)
    return f32, q8


# ------------------------------------------------------------- quantize core


def test_quantize_groups_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((5, 40)).astype(np.float32) * np.logspace(
        -2, 1, 5
    )[:, None].astype(np.float32)
    q, scale = quantize.quantize_groups(x, axis=-1)
    assert np.asarray(q).dtype == np.int8
    assert scale.shape == (5, quantize.num_groups(40))
    back = np.asarray(quantize.dequantize_groups(q, scale))
    # symmetric rounding: per-element error is at most half a step of the
    # element's own group scale
    step = np.repeat(np.asarray(scale), quantize.GROUP_SIZE, axis=-1)[:, :40]
    assert (np.abs(back - x) <= 0.5 * step + 1e-7).all()


def test_quantize_col_groups_scale_layout():
    rng = np.random.default_rng(1)
    M = rng.standard_normal((3, 20, 20)).astype(np.float32)
    q, scale = quantize.quantize_col_groups(M)
    assert q.shape == M.shape and np.asarray(q).dtype == np.int8
    # one scale per (head, column-group): independent of the row axis
    assert scale.shape == (3, quantize.num_groups(20))
    col = np.asarray(quantize.expand_group_scales(scale, 20))
    back = np.asarray(q, np.float32) * col[:, None, :]
    assert np.abs(back - M).max() <= 0.5 * col.max() + 1e-7


def test_quantize_zero_group_is_exact():
    x = np.zeros((2, 32), np.float32)
    q, scale = quantize.quantize_groups(x)
    assert (np.asarray(q) == 0).all()
    assert (np.asarray(scale) == 1.0).all()     # never divides by zero
    assert (np.asarray(quantize.dequantize_groups(q, scale)) == 0).all()


def test_check_dtype_rejects_unknown():
    with pytest.raises(ValueError, match="dtype"):
        quantize.check_dtype("int4")
    with pytest.raises(ValueError, match="dtype"):
        get_family("maclaurin").compile(_svm(0), dtype="fp16")


# ---------------------------------------------------------------- artifacts


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_int8_roundtrip_save_load_serve_bit_identical(family, tmp_path):
    m = _svm(3, d=12, n_sv=50, heads=4)
    _, q8 = _compile_pair(family, m)
    path = str(tmp_path / f"{family}_q8.npz")
    q8.save(path)
    back = CompiledArtifact.load(path)
    assert back.dtype == "int8" and back.meta == q8.meta
    for k in q8.arrays:
        assert back.arrays[k].dtype == q8.arrays[k].dtype
        np.testing.assert_array_equal(np.asarray(back.arrays[k]),
                                      np.asarray(q8.arrays[k]))

    Z = np.random.default_rng(5).standard_normal((33, 12)).astype(np.float32) * 0.3
    e1 = SVMEngine(q8, None, allow_fallback=False)
    e2 = SVMEngine(back, None, allow_fallback=False)
    np.testing.assert_array_equal(e1.predict(Z)[0], e2.predict(Z)[0])
    np.testing.assert_array_equal(e1.predict_labels(Z), e2.predict_labels(Z))


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_int8_digest_differs_from_f32_and_is_deterministic(family):
    m = _svm(4, d=10, n_sv=40, heads=3)
    f32, q8 = _compile_pair(family, m)
    assert q8.digest() != f32.digest()
    # recompiling quantizes to bit-identical bytes (content addressing)
    again = get_family(family).compile(m, num_features=NUM_FEATURES, dtype="int8")
    assert again.to_bytes() == q8.to_bytes()


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_int8_serializes_3x_smaller(family):
    # sized so the weight payload dominates the constant npz header cost
    m = _svm(6, d=64, n_sv=80, heads=10)
    fam = get_family(family)
    f32 = fam.compile(m, num_features=1024)
    q8 = fam.compile(m, num_features=1024, dtype="int8")
    ratio = len(f32.to_bytes()) / len(q8.to_bytes())
    assert ratio >= 3.0, f"{family}: int8 only {ratio:.2f}x smaller"
    assert q8.nbytes() * 3 <= f32.nbytes()      # in-memory too


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_quant_error_measured_and_reported(family):
    m = _svm(7, d=16, n_sv=60, heads=4)
    f32, q8 = _compile_pair(family, m)
    assert q8.meta["dtype"] == "int8"
    assert q8.meta["quant_mean_abs_err"] <= 0.01
    assert q8.meta["quant_mean_abs_err"] <= q8.meta["quant_max_abs_err"]
    # the reported error reproduces on the same deterministic holdout
    from repro.core.families import fourier

    Z = jnp.asarray(fourier.holdout_sample(m, 0, 256))
    ref, _ = score_artifact(f32, Z)
    got, _ = score_artifact(q8, Z)
    err = np.abs(np.asarray(got) - np.asarray(ref))
    assert np.isclose(err.mean(), q8.meta["quant_mean_abs_err"], rtol=1e-4)
    assert np.isclose(err.max(), q8.meta["quant_max_abs_err"], rtol=1e-4)


def test_v1_artifact_without_dtype_loads_as_float32(tmp_path):
    """Files written before the v2 bump carry no dtype key; they must load
    and identify as float32 (the only thing v1 could contain)."""
    m = _svm(8)
    art = get_family("maclaurin").compile(m)
    meta = {k: v for k, v in art.meta.items() if k != "dtype"}
    v1 = CompiledArtifact(art.family, art.arrays, {**meta, "format_version": 1})
    path = str(tmp_path / "v1.npz")
    v1.save(path)
    back = CompiledArtifact.load(path)
    assert back.meta["format_version"] == 1
    assert back.dtype == "float32"
    assert ARTIFACT_FORMAT_VERSION >= 2


# ------------------------------------------------------------------- engine


def test_engine_int8_argmax_parity_multiclass():
    m = _svm(9, d=32, n_sv=100, heads=8)
    f32, q8 = _compile_pair("maclaurin", m)
    e_f32 = SVMEngine(f32, None, allow_fallback=False)
    e_q8 = SVMEngine(q8, None, allow_fallback=False)
    assert e_q8.dtype == "int8" and e_f32.dtype == "float32"
    Z = np.random.default_rng(10).standard_normal((256, 32)).astype(np.float32) * 0.3
    parity = float(np.mean(e_f32.predict_labels(Z) == e_q8.predict_labels(Z)))
    assert parity >= 0.99, f"argmax parity {parity}"


def test_engine_int8_keeps_row_fallback_contract():
    """Eq 3.11 validity depends only on ||z||^2/gamma/msq, so the int8
    quadform keeps the per-row contract and out-of-envelope rows still
    re-score through the exact path."""
    m = _svm(11, d=8, n_sv=60)
    q8 = get_family("maclaurin").compile(m, dtype="int8")
    eng = SVMEngine(q8, m)
    Z = np.random.default_rng(12).standard_normal((40, 8)).astype(np.float32) * 0.3
    Z[:4] *= 50.0                               # far outside the envelope
    vals, valid = eng.predict(Z)
    assert not valid[:4].any() and valid[4:].all()
    assert eng.stats.fallback_instances == 4


@pytest.mark.parametrize("family,kernel", [
    ("maclaurin", "quadform_q8"),
    ("poly2", "quadform_q8"),
    ("fourier", "rff_score_q8"),
])
def test_tile_lookup_resolves_q8_kernel_family(family, kernel):
    m = _svm(13, d=8, n_sv=30, heads=2)
    f32, q8 = _compile_pair(family, m)
    assert get_family(family).tile_lookup(q8, 256)[0] == kernel
    assert get_family(family).tile_lookup(f32, 256)[0] != kernel
    # the engine resolves a per-bucket config through the q8 family
    eng = SVMEngine(q8, None, allow_fallback=False, min_bucket=32, max_batch=64)
    eng.warmup()
    assert sorted(eng.bucket_configs) == [32, 64]


# ------------------------------------------------------------------ kernels


def test_quadform_q8_pallas_matches_xla():
    rng = np.random.default_rng(14)
    n, d, k = 48, 40, 3
    Z = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32) * 0.3)
    M = rng.standard_normal((k, d, d)).astype(np.float32) * 0.05
    M_q, m_scale = quantize.quantize_col_groups(M)
    col = quantize.expand_group_scales(m_scale, d)
    V = jnp.asarray(rng.standard_normal((k, d)).astype(np.float32))
    c = jnp.asarray(rng.standard_normal(k).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(k).astype(np.float32))
    g = jnp.full((k,), 0.05, jnp.float32)
    msq = jnp.full((k,), 2.0, jnp.float32)

    prev = backend.set_backend("xla")
    try:
        sx, zx, vx = backend.quadform_heads_q8(Z, M_q, col, V, c, b, g, msq)
        backend.set_backend("pallas")
        sp, zp, vp = backend.quadform_heads_q8(Z, M_q, col, V, c, b, g, msq)
    finally:
        backend.set_backend(prev)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sx), atol=1e-5)
    np.testing.assert_allclose(np.asarray(zp), np.asarray(zx), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(vp), np.asarray(vx))


def test_rff_q8_pallas_matches_xla():
    rng = np.random.default_rng(15)
    n, d, f, k = 40, 24, 200, 3
    Z = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32) * 0.3)
    W_q, w_s = quantize.quantize_rows(
        rng.standard_normal((f, d)).astype(np.float32)
    )
    wt_q, wt_s = quantize.quantize_rows(
        rng.standard_normal((k, f)).astype(np.float32) * 0.01
    )
    ph = jnp.asarray(rng.uniform(0, 2 * np.pi, f).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(k).astype(np.float32))

    prev = backend.set_backend("xla")
    try:
        sx = backend.rff_score_q8(Z, W_q, w_s, ph, wt_q, wt_s, b)
        backend.set_backend("pallas")
        sp = backend.rff_score_q8(Z, W_q, w_s, ph, wt_q, wt_s, b)
    finally:
        backend.set_backend(prev)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sx), atol=1e-5)


# ----------------------------------------------------------------- registry


def test_registry_evicts_and_reloads_quantized_artifact(tmp_path):
    from repro.serve.runtime import ArtifactRegistry

    m = _svm(16, d=24, n_sv=80, heads=4)
    f32, q8 = _compile_pair("maclaurin", m)
    path = str(tmp_path / "q8.npz")
    q8.save(path)

    reg = ArtifactRegistry(
        memory_budget_bytes=f32.nbytes() + q8.nbytes() // 2,
        warmup_on_load=False,
    )
    d_q8 = reg.add_file(path, alias="det-int8")
    d_f32 = reg.register(f32, alias="det-f32")
    assert d_q8 == q8.digest() != d_f32       # variants are distinct entries

    Z = np.random.default_rng(17).standard_normal((16, 24)).astype(np.float32) * 0.3
    _, eng_q8 = reg.get_engine("det-int8")
    before = eng_q8.predict(Z)[0]
    # touching the f32 entry busts the budget -> the int8 engine (LRU) drops
    reg.get_engine("det-f32")
    snap = reg.snapshot()
    assert snap["evictions"] >= 1 and snap["loaded"] == 1
    # next use transparently reloads from the file to identical results
    _, eng_again = reg.get_engine("det-int8")
    assert eng_again is not eng_q8
    np.testing.assert_array_equal(eng_again.predict(Z)[0], before)
    assert eng_again.dtype == "int8"


# ------------------------------------------------------------- compile_model


def test_compile_model_treats_int8_as_candidates():
    m = _svm(18, d=10, n_sv=60, heads=3)
    art = compile_model(m, Budget(max_err=0.05, metric="mean_abs"), seed=2)
    rep = art.meta["compile_report"]
    assert rep["chosen_dtype"] == art.dtype
    rows = {(r["family"], r.get("dtype")) for r in rep["families"]}
    assert rows == {(f, dt) for f in FAMILIES for dt in ("float32", "int8")}
    q8_rows = [r for r in rep["families"] if r.get("dtype") == "int8"]
    assert all("quant_mean_abs_err" in r for r in q8_rows)
    # the artifact actually serves
    eng = SVMEngine(art, m)
    assert eng.predict_labels(np.asarray(m.X[:9])).shape == (9,)


def test_compile_model_enumerates_structured_fourier_int8():
    # Regression (ISSUE 8): the structured-Fastfood int8 candidate used to
    # be a typed-skip row; it is now a first-class measured candidate.
    m = _svm(19, d=6, n_sv=30)
    art = compile_model(
        m, Budget(max_err=10.0), seed=1,
        families=("fourier",),
        family_opts={"fourier": {"structured": True, "num_features": 32}},
    )
    rep = art.meta["compile_report"]
    assert not [r for r in rep["families"] if "skipped" in r]
    q8_rows = [r for r in rep["families"] if r.get("dtype") == "int8"]
    assert len(q8_rows) == 1 and "latency_ms" in q8_rows[0]
    assert "quant_mean_abs_err" in q8_rows[0]


def test_compile_model_grid_has_row_for_every_cell():
    # Every (family, dtype) cell must appear in the report exactly once —
    # measured, pruned_by_cost, or typed skip — never a silent hole.
    m = _svm(21, d=8, n_sv=40, heads=3)
    art = compile_model(
        m, Budget(max_err=10.0), seed=3,
        family_opts={"fourier": {"structured": True, "num_features": 32}},
    )
    rows = [
        (r["family"], r.get("dtype"))
        for r in art.meta["compile_report"]["families"]
    ]
    expected = [(f, dt) for f in FAMILIES for dt in ("float32", "int8")]
    assert sorted(rows) == sorted(expected)


def test_fourier_structured_int8_compiles_and_serves():
    art = get_family("fourier").compile(
        _svm(20), structured=True, dtype="int8", num_features=32
    )
    assert art.dtype == "int8"
    assert art.meta["projection"] == "fastfood"
    assert "quant_mean_abs_err" in art.meta
    assert art.arrays["ff_g"].dtype == jnp.int8
    assert art.arrays["weights"].dtype == jnp.int8
