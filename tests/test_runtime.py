"""The multi-tenant serving runtime: content-addressed registry (dedupe,
aliases, lazy directory loads, LRU eviction), the micro-batching
scheduler (coalescing correctness, row order, flush rules, zero
steady-state recompiles under concurrency), the fourier per-artifact
fallback flowing through the coalesced path, alias hot-swap mid-traffic,
and thread-safety of the engine's serving statistics."""

import threading
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import gamma_max
from repro.core.rbf import SVMModel, rbf_kernel
from repro.core.families import fourier, maclaurin
from repro.serve import PublishSpec, Runtime, SVMEngine
from repro.serve.runtime import ArtifactRegistry, MicroBatcher

ENGINE_OPTS = dict(min_bucket=8, max_batch=64)


def _svm(seed=0, d=8, n_sv=40, bias=0.1):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n_sv, d)).astype(np.float32) * 0.6
    gamma = float(gamma_max(jnp.asarray(X))) * 0.8
    ay = rng.standard_normal(n_sv).astype(np.float32) * 0.5
    return SVMModel(X=jnp.asarray(X), alpha_y=jnp.asarray(ay),
                    b=jnp.float32(bias), gamma=jnp.float32(gamma))


def _exact_scores(m, Z):
    ay2 = m.alpha_y if m.alpha_y.ndim == 2 else m.alpha_y[None, :]
    b2 = jnp.reshape(m.b, (ay2.shape[0],))
    return np.asarray(rbf_kernel(jnp.asarray(Z), m.X, m.gamma) @ ay2.T + b2[None, :])


def _batches(rng, count, d=8, lo=1, hi=5):
    return [rng.standard_normal((int(rng.integers(lo, hi + 1)), d))
               .astype(np.float32) * 0.3 for _ in range(count)]


# ----------------------------------------------------------------- registry


def test_registry_dedupes_identical_compiles():
    m = _svm(3)
    reg = ArtifactRegistry(warmup_on_load=False, engine_opts=ENGINE_OPTS)
    d1 = reg.register(maclaurin.compile(m), alias="a@latest")
    d2 = reg.register(maclaurin.compile(m), alias="b@latest")
    assert d1 == d2
    snap = reg.snapshot()
    assert snap["models"] == 1
    assert snap["aliases"] == {"a@latest": d1, "b@latest": d1}
    # both aliases serve the SAME engine object (one copy in memory)
    _, e1 = reg.get_engine("a@latest")
    _, e2 = reg.get_engine("b@latest")
    assert e1 is e2
    assert reg.loads == 1


def test_registry_ref_resolution():
    reg = ArtifactRegistry(warmup_on_load=False, engine_opts=ENGINE_OPTS)
    digest = reg.register(maclaurin.compile(_svm(3)), alias="det@latest")
    assert reg.resolve(digest) == digest
    assert reg.resolve("det@latest") == digest
    assert reg.resolve("det") == digest            # @latest convention
    assert reg.resolve(digest[:10]) == digest      # unique prefix
    with pytest.raises(KeyError):
        reg.resolve("nope")


def test_registry_lazy_directory_load(tmp_path):
    m1, m2 = _svm(1), _svm(2)
    maclaurin.compile(m1).save(str(tmp_path / "alpha.npz"))
    maclaurin.compile(m2).save(str(tmp_path / "beta.npz"))
    reg = ArtifactRegistry(warmup_on_load=False, engine_opts=ENGINE_OPTS)
    added = reg.add_directory(str(tmp_path))
    assert set(added) == {"alpha@latest", "beta@latest"}
    # indexing hashed the files; nothing is deserialized yet
    assert all(e.artifact is None and e.engine is None
               for e in reg._entries.values())
    assert added["alpha@latest"] == maclaurin.compile(m1).digest()
    # first use loads + serves correctly
    digest, eng = reg.get_engine("alpha")
    Z = np.random.default_rng(0).standard_normal((4, 8)).astype(np.float32) * 0.3
    np.testing.assert_allclose(
        eng.predict(Z)[0],
        SVMEngine(maclaurin.compile(m1), None, **ENGINE_OPTS).predict(Z)[0],
        rtol=1e-6, atol=1e-6,
    )
    assert reg.snapshot()["loaded"] == 1           # beta is still cold


def test_registry_lru_eviction_under_budget(tmp_path):
    models = [_svm(s) for s in (1, 2, 3)]
    arts = [maclaurin.compile(m) for m in models]
    for i, a in enumerate(arts):
        a.save(str(tmp_path / f"m{i}.npz"))
    budget = 2 * arts[0].nbytes() + 8              # room for two engines
    reg = ArtifactRegistry(memory_budget_bytes=budget, warmup_on_load=False,
                           engine_opts=ENGINE_OPTS)
    reg.add_directory(str(tmp_path))
    reg.get_engine("m0")
    reg.get_engine("m1")
    assert reg.eviction_count == 0
    reg.get_engine("m2")                           # busts the budget
    assert reg.eviction_count == 1
    snap = reg.snapshot()
    assert snap["loaded"] == 2
    assert snap["loaded_bytes"] <= budget
    # m0 was least recently used -> evicted (arrays dropped, path kept)
    e0 = reg._entries[reg.resolve("m0")]
    assert e0.engine is None and e0.artifact is None and e0.path is not None
    # transparent reload, still correct
    _, eng = reg.get_engine("m0")
    Z = np.random.default_rng(1).standard_normal((3, 8)).astype(np.float32) * 0.3
    np.testing.assert_allclose(
        eng.predict(Z)[0],
        SVMEngine(arts[0], None, **ENGINE_OPTS).predict(Z)[0],
        rtol=1e-6, atol=1e-6,
    )
    assert reg.loads == 4                          # 3 cold loads + 1 reload


def test_registry_in_memory_entry_never_loses_arrays():
    """An artifact registered without a backing file keeps its arrays on
    eviction (they are the only copy) — only the engine is dropped."""
    arts = [maclaurin.compile(_svm(s)) for s in (1, 2)]
    reg = ArtifactRegistry(memory_budget_bytes=arts[0].nbytes() + 8,
                           warmup_on_load=False, engine_opts=ENGINE_OPTS)
    d0 = reg.register(arts[0], alias="m0")
    reg.register(arts[1], alias="m1")
    reg.get_engine("m0")
    reg.get_engine("m1")
    assert reg.eviction_count == 1
    entry = reg._entries[d0]
    assert entry.engine is None and entry.artifact is not None


# ---------------------------------------------------------------- scheduler


def test_microbatcher_coalesces_one_bucket_fill():
    m = _svm(5)
    eng = SVMEngine(maclaurin.compile(m), None, **ENGINE_OPTS)
    eng.warmup([8])
    with MicroBatcher(eng, max_wait_us=200_000, flush_rows=8) as mb:
        rng = np.random.default_rng(2)
        Zs = [rng.standard_normal((1, 8)).astype(np.float32) * 0.3
              for _ in range(8)]
        futs = [mb.submit(Z) for Z in Zs]          # 8 rows == flush_rows
        for Z, f in zip(Zs, futs):
            got = f.result(timeout=10).values
            np.testing.assert_allclose(got, eng.predict(Z)[0],
                                       rtol=1e-6, atol=1e-6)
        snap = mb.telemetry.snapshot()
        assert snap["flushes"] == 1                # ONE engine step for all 8
        assert snap["requests"] == 8
        assert snap["coalescing_factor"] == 8.0
        assert snap["deadline_flushes"] == 0       # the bucket filled


def test_microbatcher_deadline_flushes_lone_request():
    m = _svm(5)
    eng = SVMEngine(maclaurin.compile(m), None, **ENGINE_OPTS)
    eng.warmup([8])
    with MicroBatcher(eng, max_wait_us=2_000, flush_rows=64) as mb:
        Z = np.random.default_rng(3).standard_normal((2, 8)).astype(np.float32)
        t0 = time.perf_counter()
        res = mb.submit(Z).result(timeout=10)
        np.testing.assert_allclose(res.values, eng.predict(Z)[0],
                                   rtol=1e-6, atol=1e-6)
        assert time.perf_counter() - t0 < 5.0      # deadline, not forever
        assert mb.telemetry.snapshot()["deadline_flushes"] >= 1


def test_microbatcher_preserves_row_order_under_concurrency():
    """Every concurrent caller gets exactly its rows, in its order — the
    scatter is exercised with per-request distinct values."""
    m = _svm(6)
    eng = SVMEngine(maclaurin.compile(m), None, **ENGINE_OPTS)
    eng.warmup()
    rng = np.random.default_rng(4)
    Zs = _batches(rng, 24)
    expected = [eng.predict(Z)[0] for Z in Zs]
    results = [None] * len(Zs)
    with MicroBatcher(eng, max_wait_us=1_000, flush_rows=16) as mb:
        def client(i):
            results[i] = mb.submit(Zs[i]).result(timeout=10)
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(len(Zs))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    for i, res in enumerate(results):
        assert len(res) == Zs[i].shape[0]
        np.testing.assert_allclose(res.values, expected[i],
                                   rtol=1e-6, atol=1e-6)


def test_microbatcher_zero_steady_state_recompiles():
    m = _svm(7)
    eng = SVMEngine(maclaurin.compile(m), None, **ENGINE_OPTS)
    eng.warmup()                                   # all buckets precompiled
    before = eng.jit_cache_size()
    rng = np.random.default_rng(5)
    Zs = _batches(rng, 40)
    with MicroBatcher(eng, max_wait_us=500, flush_rows=8) as mb:
        futs = [mb.submit(Z) for Z in Zs]
        for f in futs:
            f.result(timeout=10).values
    assert eng.jit_cache_size() == before          # coalescing added no traces


def test_microbatcher_survives_cancelled_future():
    """A client cancelling its queued future must not kill the flush
    worker — later requests still get served."""
    m = _svm(5)
    eng = SVMEngine(maclaurin.compile(m), None, **ENGINE_OPTS)
    eng.warmup([8])
    with MicroBatcher(eng, max_wait_us=20_000, flush_rows=64) as mb:
        doomed = mb.submit(np.zeros((1, 8), np.float32))
        assert doomed.cancel()                     # still queued -> cancellable
        Z = np.random.default_rng(12).standard_normal((2, 8)).astype(np.float32)
        res = mb.submit(Z).result(timeout=10)      # worker must still be alive
        np.testing.assert_allclose(res.values, eng.predict(Z)[0],
                                   rtol=1e-6, atol=1e-6)


def test_microbatcher_empty_submit_is_free():
    """A zero-row request resolves immediately with empty outputs and
    burns no engine step (and no padding statistics)."""
    m = _svm(5)
    eng = SVMEngine(maclaurin.compile(m), None, **ENGINE_OPTS)
    with MicroBatcher(eng, max_wait_us=1_000) as mb:
        before = eng.stats.snapshot()
        res = mb.submit(np.zeros((0, 8), np.float32)).result(timeout=10)
        assert res.values.shape == (0,)
        assert res.valid.shape == (0,) and res.labels.shape == (0,)
        assert len(res) == 0
        assert eng.stats.snapshot() == before      # engine never touched


def test_runtime_eviction_retires_idle_batcher():
    """LRU eviction must release the engine even when the Runtime holds a
    batcher for it — the batcher is retired via the evict listener."""
    arts = [maclaurin.compile(_svm(s)) for s in (1, 2)]
    with Runtime(memory_budget_bytes=arts[0].nbytes() + 8, max_wait_us=200,
                 warmup_on_load=False, engine_opts=ENGINE_OPTS) as rt:
        d0 = rt.publish("m0", arts[0])
        rt.publish("m1", arts[1])
        Z = np.random.default_rng(13).standard_normal((2, 8)).astype(np.float32)
        v0 = rt.predict("m0", Z)[0]
        rt.predict("m1", Z)                        # busts the budget, evicts m0
        assert rt.registry.eviction_count == 1
        assert d0 not in rt._batchers              # batcher retired with engine
        # transparent reload on next use, same answers
        np.testing.assert_allclose(rt.predict("m0", Z)[0], v0,
                                   rtol=1e-6, atol=1e-6)


def test_runtime_warmup_without_warmup_on_load():
    with Runtime(warmup_on_load=False, engine_opts=ENGINE_OPTS) as rt:
        rt.publish("m", maclaurin.compile(_svm(4)))
        assert rt.warmup("m") >= 4                 # all buckets compiled NOW


def test_engine_result_split_rejects_bad_sizes():
    m = _svm(5)
    eng = SVMEngine(maclaurin.compile(m), None, **ENGINE_OPTS)
    res = eng.submit(np.zeros((5, 8), np.float32))
    with pytest.raises(ValueError):
        res.split([2, 2])                          # 4 != 5


def test_slice_result_defers_and_shares_one_materialize():
    m = _svm(5)
    eng = SVMEngine(maclaurin.compile(m), None, **ENGINE_OPTS)
    Z = np.random.default_rng(6).standard_normal((6, 8)).astype(np.float32) * 0.3
    res = eng.submit(Z)
    a, b = res.split([2, 4])
    assert res._done is None                       # nothing synced yet
    _ = a.values                                   # first slice materializes
    assert res._done is not None
    np.testing.assert_allclose(np.concatenate([a.values, b.values]),
                               eng.predict(Z)[0], rtol=1e-6, atol=1e-6)


# ------------------------------------------------- fourier artifact fallback


def test_fourier_artifact_fallback_through_runtime():
    """A fourier artifact whose compile-time verdict violates the budget
    must send EVERY coalesced row down the exact rbf_pred path, and the
    scatter must hand each concurrent request its own rows in order."""
    m = _svm(8, d=6, n_sv=30)
    art = fourier.compile(m, num_features=32, err_tolerance=0.0)   # verdict: invalid
    assert art.meta["valid_globally"] is False
    rng = np.random.default_rng(7)
    Zs = [rng.standard_normal((n, 6)).astype(np.float32) * 0.3
          for n in (1, 3, 2, 4, 1, 2, 3, 1)]
    with Runtime(max_wait_us=100_000, flush_rows=17,
                 engine_opts=ENGINE_OPTS) as rt:
        rt.publish("rff", art, PublishSpec(exact=m))
        rt.warmup("rff")
        results = [None] * len(Zs)

        def client(i):
            results[i] = rt.submit("rff", Zs[i]).result(timeout=10)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(len(Zs))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for i, res in enumerate(results):
            assert not res.valid.any()             # per-ARTIFACT verdict
            np.testing.assert_allclose(            # exact path, request order
                res.values, _exact_scores(m, Zs[i])[:, 0],
                rtol=1e-4, atol=1e-4,
            )
        stats = rt.stats("rff")
        assert stats["fallback_rate"] == 1.0       # every row fell back


# ----------------------------------------------------------------- hot swap


def test_alias_hot_swap_atomic():
    m1, m2 = _svm(1, bias=5.0), _svm(1, bias=-5.0)
    with Runtime(max_wait_us=200, engine_opts=ENGINE_OPTS) as rt:
        d1 = rt.publish("det", maclaurin.compile(m1))
        Z = np.random.default_rng(8).standard_normal((3, 8)).astype(np.float32) * 0.3
        v1 = rt.predict("det", Z)[0]
        d2 = rt.publish("det", maclaurin.compile(m2))      # hot-swap
        assert d1 != d2
        v2 = rt.predict("det", Z)[0]
        np.testing.assert_allclose(v2 - v1, np.full(3, -10.0), atol=1e-4)
        # the old digest remains servable (immutable content address)
        np.testing.assert_allclose(rt.predict(d1, Z)[0], v1, rtol=1e-6)


def test_alias_hot_swap_mid_traffic():
    """Clients pounding an alias while it is re-pointed must only ever see
    a COMPLETE old-model or new-model answer, never a torn mix, and the
    swap must take effect for post-swap traffic."""
    m_old, m_new = _svm(2, bias=5.0), _svm(2, bias=-5.0)
    a_old, a_new = maclaurin.compile(m_old), maclaurin.compile(m_new)
    Z = np.random.default_rng(9).standard_normal((2, 8)).astype(np.float32) * 0.3
    with Runtime(max_wait_us=200, engine_opts=ENGINE_OPTS) as rt:
        rt.publish("det", a_old)
        rt.warmup("det")
        want_old = rt.predict("det", Z)[0].copy()
        want_new = SVMEngine(a_new, None, **ENGINE_OPTS).predict(Z)[0]
        stop = threading.Event()
        errors = []
        saw = {"old": 0, "new": 0}

        def client():
            while not stop.is_set():
                got = rt.predict("det", Z)[0]
                if np.allclose(got, want_old, atol=1e-4):
                    saw["old"] += 1
                elif np.allclose(got, want_new, atol=1e-4):
                    saw["new"] += 1
                else:
                    errors.append(got)
                    return

        threads = [threading.Thread(target=client) for _ in range(4)]
        for t in threads:
            t.start()
        time.sleep(0.15)
        rt.publish("det", a_new)                   # swap under live traffic
        np.testing.assert_allclose(rt.predict("det", Z)[0], want_new, atol=1e-4)
        time.sleep(0.25)
        stop.set()
        for t in threads:
            t.join(timeout=10)
        assert not errors, f"torn/unknown result observed: {errors[0]}"
        assert saw["old"] > 0                      # traffic before the swap
        assert saw["new"] > 0                      # ... and after


# ------------------------------------------------------------ thread safety


def test_engine_stats_thread_safe_under_concurrent_predict():
    """Bare-int increments lose updates under contention; the locked stats
    must account every row exactly."""
    m = _svm(3)
    eng = SVMEngine(maclaurin.compile(m), None, **ENGINE_OPTS)
    eng.warmup([8])
    Z = np.zeros((3, 8), np.float32)
    threads_n, reps = 8, 50

    def worker():
        for _ in range(reps):
            eng.predict(Z)

    base = eng.stats.snapshot()
    threads = [threading.Thread(target=worker) for _ in range(threads_n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = eng.stats.snapshot()
    assert snap["instances"] - base["instances"] == threads_n * reps * 3
    assert snap["batches"] - base["batches"] == threads_n * reps
    assert sum(snap["bucket_hits"].values()) - sum(base["bucket_hits"].values()) \
        == threads_n * reps


@pytest.mark.stress
def test_runtime_multithreaded_stress():
    """Bounded multi-model stress: concurrent clients over two models with
    mixed batch sizes; every response correct, every row accounted."""
    m1, m2 = _svm(1), _svm(2, d=8)
    a1, a2 = maclaurin.compile(m1), maclaurin.compile(m2)
    ref1 = SVMEngine(a1, None, **ENGINE_OPTS)
    ref2 = SVMEngine(a2, None, **ENGINE_OPTS)
    clients, reps = 8, 25
    rng = np.random.default_rng(10)
    work = [  # per client: (model, Z, expected)
        [("m1", Z, ref1.predict(Z)[0]) if rng.random() < 0.5
         else ("m2", Z, ref2.predict(Z)[0])
         for Z in _batches(rng, reps)]
        for _ in range(clients)
    ]
    with Runtime(max_wait_us=300, flush_rows=16, engine_opts=ENGINE_OPTS) as rt:
        rt.publish("m1", a1)
        rt.publish("m2", a2)
        rt.warmup("m1"), rt.warmup("m2")
        errors = []

        def client(items):
            try:
                futs = [(rt.submit(name, Z), want) for name, Z, want in items]
                for fut, want in futs:
                    got = fut.result(timeout=30).values
                    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
            except Exception as e:                 # surfaced after join
                errors.append(e)

        threads = [threading.Thread(target=client, args=(w,)) for w in work]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errors, errors[0]
        assert time.perf_counter() - t0 < 30.0     # bounded
        stats = rt.stats()
        total_requests = sum(
            ms["requests"] for ms in stats["models"].values()
        )
        total_rows = sum(ms["rows"] for ms in stats["models"].values())
        assert total_requests == clients * reps
        assert total_rows == sum(Z.shape[0] for w in work for _, Z, _ in w)
        # the runtime coalesced: strictly fewer engine steps than requests
        total_flushes = sum(ms["flushes"] for ms in stats["models"].values())
        assert total_flushes <= total_requests
