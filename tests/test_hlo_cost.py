"""Validate the trip-count-aware HLO cost model against XLA's built-in
analysis on loop-free programs, and against hand-math on scanned ones."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_text, normalize_cost_analysis


def _cost(f, *sds):
    c = jax.jit(f).lower(*sds).compile()
    ours = analyze_text(c.as_text())
    theirs = normalize_cost_analysis(c.cost_analysis())
    return ours, theirs


def test_matches_builtin_on_loop_free_matmul():
    def f(a, b):
        return jnp.tanh(a @ b)

    sds = (
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 64), jnp.float32),
    )
    ours, theirs = _cost(f, *sds)
    # dot flops dominate: 2*128*256*64
    assert ours["flops"] == pytest.approx(theirs["flops"], rel=0.25)


def test_scan_flops_scale_with_trip_count():
    L = 10

    def f(w, x):
        def body(x, _):
            return x @ w, None
        y, _ = jax.lax.scan(body, x, None, length=L)
        return y

    sds = (
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((8, 64), jnp.float32),
    )
    ours, theirs = _cost(f, *sds)
    per_layer = 2 * 8 * 64 * 64
    assert theirs["flops"] == pytest.approx(per_layer, rel=0.1)      # body-once bug
    assert ours["flops"] == pytest.approx(per_layer * L, rel=0.15)   # corrected


def test_nested_scan_multiplies():
    def f(w, x):
        def outer(x, _):
            def inner(x, _):
                return x @ w, None
            y, _ = jax.lax.scan(inner, x, None, length=4)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    sds = (
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((4, 32), jnp.float32),
    )
    ours, _ = _cost(f, *sds)
    per = 2 * 4 * 32 * 32
    assert ours["flops"] == pytest.approx(per * 12, rel=0.2)


def test_collectives_multiplied_by_trips():
    import os
    import subprocess
    import sys

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
import sys
sys.path.insert(0, "src")
from repro.launch.hlo_cost import analyze_text
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))

def f(ws, x):
    def body(x, w):
        return jnp.tanh(x @ w), None
    y, _ = jax.lax.scan(body, x, ws)
    return y

wsh = NamedSharding(mesh, P(None, None, "model"))
xsh = NamedSharding(mesh, P("data", None))
with mesh:
    c = jax.jit(f, in_shardings=(wsh, xsh)).lower(
        jax.ShapeDtypeStruct((5, 64, 64), jnp.float32),
        jax.ShapeDtypeStruct((8, 64), jnp.float32),
    ).compile()
res = analyze_text(c.as_text())
total = sum(v["count"] for v in res["collectives"].values())
assert total >= 5, res["collectives"]   # at least one collective per scan iter
print("OK", res["collectives"])
"""
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout
