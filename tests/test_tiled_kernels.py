"""The common tiled-kernel layer: TileConfig resolution, the tuning
registry, K-axis head-block tiling of the fused quadform kernel (tiled ==
untiled bit-for-bit; VMEM-budgeted block_k), backend dispatch via
$REPRO_SVM_BACKEND, and Pallas-interpret vs XLA path agreement."""

import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import backend
from repro.core.maclaurin import ApproxModel
from repro.kernels.common import TileConfig, autotune, tiles, tuning
from repro.kernels.quadform.kernel import quadform_heads_pallas
from repro.kernels.quadform.ref import quadform_heads_ref
from repro.kernels.rbf_pred.kernel import rbf_predict_pallas
from repro.serve.svm_engine import SVMEngine


@pytest.fixture(autouse=True)
def _clean_tuning():
    tuning.clear_overrides()
    yield
    tuning.clear_overrides()


def _random_heads(K, d, seed=0, gamma=0.05):
    rng = np.random.default_rng(seed)
    M = rng.standard_normal((K, d, d)).astype(np.float32) * 0.1
    M_all = jnp.asarray((M + M.transpose(0, 2, 1)) / 2)
    V = jnp.asarray(rng.standard_normal((K, d)).astype(np.float32))
    c = jnp.asarray(rng.standard_normal(K).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(K).astype(np.float32))
    g = jnp.full((K,), gamma, jnp.float32)
    msq = jnp.full((K,), 2.0, jnp.float32)
    return M_all, V, c, b, g, msq


# ------------------------------------------------------------ tiles/config


def test_tile_arithmetic():
    assert tiles.round_up(1, 128) == 128
    assert tiles.round_up(128, 128) == 128
    assert tiles.round_up(129, 128) == 256
    assert tiles.lane_pad(3) == 128
    assert tiles.lane_pad(784) == 896
    assert tiles.grid_blocks(513, 64) == 9
    x = tiles.pad_tail(jnp.ones((3, 5)), 8, 128)
    assert x.shape == (8, 128) and float(x.sum()) == 15.0


def test_tileconfig_block_k_budget():
    """block_k auto-resolution keeps the (d_pad, block_k*d_pad) f32 slice
    under the VMEM budget, floored at one head."""
    d_pad = 896                                  # mnist d=784 lane-padded
    cfg = TileConfig(vmem_limit_mb=8)
    bk = cfg.resolve_block_k(10, d_pad)
    assert bk * d_pad * d_pad * 4 <= 8 << 20
    assert (bk + 1) * d_pad * d_pad * 4 > 8 << 20     # largest that fits
    # one head over budget still runs (smallest possible tile)
    assert TileConfig(vmem_limit_mb=1).resolve_block_k(10, 2048) == 1
    # explicit block_k wins, capped at K
    assert TileConfig(block_k=4).resolve_block_k(10, d_pad) == 4
    assert TileConfig(block_k=64).resolve_block_k(10, d_pad) == 10


def test_tileconfig_is_jit_static():
    cfg = TileConfig(block_n=64)
    assert hash(cfg) == hash(TileConfig(block_n=64))

    @jax.jit
    def f(x, cfg: TileConfig = cfg):
        return x

    calls = jax.jit(lambda x, c: x * c.block_n, static_argnums=1)
    assert float(calls(jnp.float32(2.0), cfg)) == 128.0


# ---------------------------------------------------------- tuning registry


def test_bucket_policy_shared_with_engine():
    """Dispatch-level lookups key on the SAME buckets the engine pads to
    and the sweep records — a batch of 1000 resolves the 1024 entry."""
    from repro.serve.svm_engine import bucket_size

    assert tuning.bucket(1000) == 1024
    assert tuning.bucket(5) == 32
    assert tuning.bucket(9000) == 8192
    for n in (1, 32, 33, 100, 1000, 8192, 10_000):
        assert tuning.bucket(n) == bucket_size(n)
    tuned = TileConfig(block_n=128)
    tuning.record("quadform", tuning.shape_key(d=64, k=1, n=1024), tuned)
    key_for_1000 = tuning.shape_key(d=64, k=1, n=tuning.bucket(1000))
    assert tuning.lookup("quadform", key_for_1000) == tuned


def test_tuning_lookup_default_and_override():
    key = tuning.shape_key(d=64, k=10, n=1024)
    assert key == "d64_k10_n1024"
    assert tuning.lookup("quadform", key) == tuning.DEFAULTS["quadform"]
    with pytest.raises(KeyError):
        tuning.lookup("quadform", key, strict=True)
    tuned = TileConfig(block_n=128)
    tuning.record("quadform", key, tuned, measured_ms=1.0, default_ms=2.0)
    assert tuning.lookup("quadform", key) == tuned
    assert tuning.lookup("quadform", key, strict=True) == tuned
    # other buckets unaffected
    assert tuning.lookup("quadform", "d64_k10_n32") == tuning.DEFAULTS["quadform"]
    with pytest.raises(KeyError):
        tuning.lookup("nonexistent_kernel")


def test_tuning_table_roundtrip(tmp_path):
    path = str(tmp_path / "table.json")
    tuned = TileConfig(block_n=64, block_m=128)
    tuning.lookup("quadform", "warm_the_default_table_cache")
    tuning.record("rbf_pred", "d100_m512_n256", tuned, measured_ms=0.5,
                  source="unit-test")
    tuning.save_table(path)
    with open(path) as f:
        saved = json.load(f)
    entry = saved["entries"][tuning.platform()]["rbf_pred"]["d100_m512_n256"]
    assert entry["config"]["block_n"] == 64
    assert entry["measured_ms"] == 0.5
    assert TileConfig.from_json(entry["config"]) == tuned
    # saving to a scratch path must not dump the checked-in default table
    # into it, nor leak the override into the cached default table
    assert set(saved["entries"][tuning.platform()]) == {"rbf_pred"}
    tuning.clear_overrides()
    assert tuning.lookup("rbf_pred", "d100_m512_n256") == tuning.DEFAULTS["rbf_pred"]


def test_load_table_validates_and_roundtrips(tmp_path):
    """save_table -> load_table round-trips clean entries; malformed keys,
    unknown kernels and bad configs are dropped with a warning instead of
    surfacing later as a KeyError mid-trace."""
    path = str(tmp_path / "table.json")
    tuned = TileConfig(block_n=64)
    tuning.record("quadform", "d64_k1_n256", tuned, measured_ms=0.25,
                  platform_name="cpu")
    tuning.save_table(path)
    table = tuning.load_table(path)                       # clean: no warning
    entry = table["entries"]["cpu"]["quadform"]["d64_k1_n256"]
    assert TileConfig.from_json(entry["config"]) == tuned

    # corrupt the file with every malformation class
    table["entries"]["cpu"]["not_a_kernel"] = {"d64_n32": {"config": {"block_n": 8}}}
    table["entries"]["cpu"]["rbf_pred"] = {
        "TOTALLY wrong key!": {"config": {"block_n": 8}},     # bad key
        "d64_m512_n256": {"config": {"block_n": -5}},         # bad config value
        "d32_m512_n256": {"note": "no config at all"},        # missing config
        "d16_m512_n256": {"config": {"block_n": 128}},        # survivor
    }
    with open(path, "w") as f:
        json.dump(table, f)
    with pytest.warns(UserWarning) as warned:
        clean = tuning.load_table(path)
    assert len(warned) == 4
    assert "not_a_kernel" not in clean["entries"]["cpu"]
    assert set(clean["entries"]["cpu"]["rbf_pred"]) == {"d16_m512_n256"}
    # the pre-existing good entry survives validation untouched
    assert clean["entries"]["cpu"]["quadform"]["d64_k1_n256"] == entry


def test_load_table_rejects_malformed_top_level(tmp_path):
    path = str(tmp_path / "bad.json")
    with open(path, "w") as f:
        json.dump({"entries": ["this", "is", "not", "a", "dict"]}, f)
    with pytest.warns(UserWarning, match="top-level structure"):
        assert tuning.load_table(path) == {"version": 1, "entries": {}}
    with open(path, "w") as f:
        f.write("{ not json")
    assert tuning.load_table(path) == {"version": 1, "entries": {}}


def test_autotune_picks_fastest_and_records():
    key = "unit_test_key"
    seen = []

    def build(cfg):
        def run():
            seen.append(cfg)
            return jnp.zeros(())
        return run

    winner, rows = autotune.autotune(
        "quadform", key, build,
        [TileConfig(block_n=64), TileConfig(block_n=256)],
        repeats=1, warmup=0,
    )
    # the default was appended: 3 candidates timed, winner recorded
    assert len(rows) == 3
    assert any(r["config"] == tuning.DEFAULTS["quadform"] for r in rows)
    assert tuning.lookup("quadform", key, strict=True) == winner
    assert winner == min(rows, key=lambda r: r["ms"])["config"]


# ------------------------------------------------------- backend dispatch


def test_env_var_backend_override(monkeypatch):
    monkeypatch.setattr(backend, "_forced", None)
    monkeypatch.setenv("REPRO_SVM_BACKEND", "pallas")
    assert backend.resolve() == "pallas"
    monkeypatch.setenv("REPRO_SVM_BACKEND", "xla")
    assert backend.resolve() == "xla"
    monkeypatch.setenv("REPRO_SVM_BACKEND", "auto")
    assert backend.resolve() == ("pallas" if jax.default_backend() == "tpu" else "xla")
    monkeypatch.setenv("REPRO_SVM_BACKEND", "cuda")
    with pytest.raises(ValueError):
        backend.resolve()
    # set_backend beats the env var
    monkeypatch.setenv("REPRO_SVM_BACKEND", "xla")
    prev = backend.set_backend("pallas")
    try:
        assert backend.resolve() == "pallas"
    finally:
        backend.set_backend(prev or "auto")


@pytest.mark.parametrize("K", [1, 4])
def test_quadform_pallas_vs_xla_paths_agree(K):
    """The two dispatch targets are the same math: Pallas (interpret) and
    the stacked-Hessian XLA GEMM agree to fp tolerance."""
    n, d = 97, 50
    rng = np.random.default_rng(K)
    Z = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32) * 0.5)
    heads = _random_heads(K, d, seed=K + 10)
    s_p, zsq_p, v_p = quadform_heads_pallas(
        Z, *heads, config=TileConfig(block_n=32), interpret=True
    )
    s_x, zsq_x, v_x = backend.quadform_heads_xla(Z, *heads)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_x), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(zsq_p), np.asarray(zsq_x), rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(v_p), np.asarray(v_x))


def test_rbf_pred_pallas_vs_xla_paths_agree():
    n, m, d = 130, 300, 37
    rng = np.random.default_rng(7)
    Z = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    X = jnp.asarray(rng.standard_normal((m, d)).astype(np.float32))
    a = jnp.asarray(rng.standard_normal(m).astype(np.float32))
    f_p = rbf_predict_pallas(
        Z, X, a, 0.07, 0.3, config=TileConfig(block_n=64, block_m=128), interpret=True
    )
    f_x = backend.rbf_scores_xla(Z, X, a, 0.07, 0.3)
    np.testing.assert_allclose(np.asarray(f_p), np.asarray(f_x), rtol=2e-5, atol=2e-5)


def test_backend_dispatch_routes_to_pallas(monkeypatch):
    """Forcing pallas off-TPU runs the kernels in interpret mode through
    the SAME dispatch entry points the engine uses."""
    prev = backend.set_backend("pallas")
    try:
        n, d, K = 40, 12, 3
        rng = np.random.default_rng(0)
        Z = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32) * 0.4)
        heads = _random_heads(K, d, seed=3)
        s, _, _ = backend.quadform_heads(Z, *heads)
        s_ref, _, _ = quadform_heads_ref(Z, *heads)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-5, atol=1e-5)
    finally:
        backend.set_backend(prev or "auto")


# ------------------------------------------------ K-axis head-block tiling


def test_k_tiled_matches_untiled_bit_for_bit():
    """Head-blocks are independent: the tiled kernel's fp32 scores are
    IDENTICAL to the fully-resident kernel's, not merely close."""
    n, d, K = 65, 30, 10
    rng = np.random.default_rng(42)
    Z = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32) * 0.5)
    heads = _random_heads(K, d, seed=5)
    untiled = quadform_heads_pallas(
        Z, *heads, config=TileConfig(block_n=32, block_k=K), interpret=True
    )
    for block_k in (1, 2, 3, 4):                 # 3 exercises K % block_k != 0
        tiled = quadform_heads_pallas(
            Z, *heads, config=TileConfig(block_n=32, block_k=block_k), interpret=True
        )
        for t, u in zip(tiled, untiled):
            np.testing.assert_array_equal(np.asarray(t), np.asarray(u))


def test_k_tiled_mnist_shape_under_vmem_budget():
    """The acceptance shape: K=10 heads at d=784 (mnist OvR). The stacked
    Hessian is ~31 MB f32 — over a single core's VMEM — but every grid
    step's slice stays under the configured budget, and the scores match
    the untiled kernel bit-for-bit and the vmap oracle to tolerance."""
    n, d, K = 48, 784, 10
    budget_mb = 8
    d_pad = tiles.lane_pad(d)
    cfg = TileConfig(block_n=48, vmem_limit_mb=budget_mb)
    block_k = cfg.resolve_block_k(K, d_pad)
    assert K * d_pad * d_pad * 4 > 16 << 20      # full stack busts VMEM...
    assert block_k * d_pad * d_pad * 4 <= budget_mb << 20   # ...each slice fits
    assert 1 <= block_k < K

    rng = np.random.default_rng(0)
    Z = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32) * 0.1)
    heads = _random_heads(K, d, seed=1, gamma=1e-3)
    tiled = quadform_heads_pallas(Z, *heads, config=cfg, interpret=True)
    untiled = quadform_heads_pallas(
        Z, *heads, config=TileConfig(block_n=48, block_k=K), interpret=True
    )
    for t, u in zip(tiled, untiled):
        np.testing.assert_array_equal(np.asarray(t), np.asarray(u))
    s_ref, _, _ = quadform_heads_ref(Z, *heads)
    np.testing.assert_allclose(
        np.asarray(tiled[0]), np.asarray(s_ref), rtol=1e-4, atol=1e-4
    )


# ------------------------------------------------- engine bucket resolution


def _toy_engine(**kw):
    d = 6
    rng = np.random.default_rng(0)
    M = rng.standard_normal((d, d)).astype(np.float32) * 0.1
    am = ApproxModel(
        c=jnp.float32(0.3),
        v=jnp.asarray(rng.standard_normal(d).astype(np.float32)),
        M=jnp.asarray((M + M.T) / 2),
        b=jnp.float32(-0.1),
        gamma=jnp.float32(0.05),
        max_sv_sq_norm=jnp.float32(2.0),
    )
    return SVMEngine(am, None, **kw)


def test_engine_resolves_tuned_config_per_bucket():
    tuned = TileConfig(block_n=16)
    tuning.record("quadform", tuning.shape_key(d=6, k=1, n=32), tuned)
    eng = _toy_engine(min_bucket=32, max_batch=64)
    eng.warmup()
    # bucket 32 picked up the measured entry (clamped block_n intact),
    # bucket 64 fell back to the default (clamped to the bucket)
    assert eng.bucket_configs[32].block_n == 16
    assert eng.bucket_configs[64].block_n == min(
        tuning.DEFAULTS["quadform"].block_n, 64
    )
    f, _ = eng.predict(np.zeros((5, 6), np.float32))
    assert f.shape == (5,)


def test_engine_explicit_tile_config_pins_all_buckets():
    eng = _toy_engine(min_bucket=32, max_batch=64, tile_config=TileConfig(block_n=8))
    eng.warmup()
    assert all(c.block_n == 8 for c in eng.bucket_configs.values())
