"""Serving-path tests: greedy generation on both cache backends, engine
statistics, prefill/serve step factories."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models.transformer import init_cache, init_params
from repro.serve.decode_step import greedy_generate, make_prefill_step, make_serve_step


@pytest.mark.parametrize("backend", ["softmax", "maclaurin"])
def test_greedy_generate_both_backends(backend):
    cfg = ARCHS["qwen2-0.5b"].reduced().with_backend(backend)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 64
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, 4), 0, cfg.vocab_size)
    cache = init_cache(cfg, B, S, params=params, dtype=jnp.float32)
    toks, cache2 = greedy_generate(cfg, params, prompt, cache, steps=6)
    assert toks.shape == (B, 6)
    assert int(toks.max()) < cfg.vocab_size and int(toks.min()) >= 0


def test_maclaurin_state_size_independent_of_context():
    cfg = ARCHS["qwen2-0.5b"].reduced().with_backend("maclaurin")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    c1 = init_cache(cfg, 2, 128, params=params)
    c2 = init_cache(cfg, 2, 1 << 19, params=params)
    b1 = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(c1))
    b2 = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(c2))
    assert b1 == b2  # the paper's collapse: state is O(d^2), not O(S)
    cfg_kv = cfg.with_backend("softmax")
    k1 = init_cache(cfg_kv, 2, 128, params=params)
    k2 = init_cache(cfg_kv, 2, 4096, params=params)
    kb1 = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(k1))
    kb2 = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(k2))
    assert kb2 == 32 * kb1  # KV cache grows linearly with S


def test_vlm_serve_step_with_images():
    cfg = ARCHS["llama-3.2-vision-90b"].reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    img = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.n_image_tokens, cfg.d_model))
    cache = init_cache(cfg, B, 32, image_embeds=img, params=params, dtype=jnp.float32)
    step = make_serve_step(cfg)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = step(params, tok, jnp.int32(0), cache, img)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_prefill_step_factory():
    cfg = ARCHS["musicgen-medium"].reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    step = make_prefill_step(cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, cfg.vocab_size)
    logits = step(params, tokens)
    assert logits.shape == (2, 16, cfg.vocab_size)


def test_int8_kv_cache_decode_accuracy():
    """int8 KV (per-token-per-head scales) matches the fp teacher-forced
    forward — the §Perf decode-memory lever is numerically safe."""
    import dataclasses

    cfg = dataclasses.replace(ARCHS["qwen2-0.5b"].reduced(), dtype="float32")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    B, T = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    from repro.models.transformer import decode, forward

    full, _ = forward(cfg, params, tokens)
    cfg_q = dataclasses.replace(cfg, kv_cache_dtype="int8")
    cache = init_cache(cfg_q, B, T, params=params)
    outs = []
    for t in range(T):
        lg, cache = decode(cfg_q, params, tokens[:, t : t + 1], jnp.int32(t), cache)
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    assert float(jnp.mean(jnp.argmax(dec, -1) == jnp.argmax(full, -1))) == 1.0
    err = float(jnp.max(jnp.abs(jax.nn.softmax(dec) - jax.nn.softmax(full))))
    assert err < 0.05
