"""Paper-math tests: the Maclaurin collapse (§3), its bounds (§3.1, App A),
and the degree-2 polynomial relation (§3.2). Includes hypothesis property
tests of the system invariants."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)",
)
from hypothesis import given, settings, strategies as st

from repro.core import (
    SVMModel,
    approximate,
    approx_decision_function,
    approx_decision_function_checked,
    decision_function,
    gamma_max,
    maclaurin_exp,
    maclaurin_rel_error,
    REL_ERR_AT_HALF,
)
from repro.core.bounds import bound_holds, exact_bound_holds, max_abs_exponent
from repro.core import poly2
from repro.core.rbf import decision_function_loops, rbf_kernel


def _random_model(rng, n_sv=50, d=7, gamma=0.05):
    X = rng.standard_normal((n_sv, d)).astype(np.float32) * 0.5
    ay = rng.standard_normal(n_sv).astype(np.float32)
    return SVMModel(
        X=jnp.asarray(X), alpha_y=jnp.asarray(ay),
        b=jnp.float32(0.3), gamma=jnp.float32(gamma),
    )


# ---------------------------------------------------------------- Eq A.1/A.2


def test_maclaurin_series_definition():
    x = jnp.linspace(-2, 2, 101)
    np.testing.assert_allclose(maclaurin_exp(x), 1 + x + 0.5 * x * x, rtol=1e-6)


def test_rel_error_bound_at_half():
    """Fig 1 / Eq A.2: sup_{|x|<1/2} rel err < 3.05% and is attained at -1/2."""
    x = jnp.linspace(-0.5, 0.5, 2001)
    errs = maclaurin_rel_error(x)
    assert float(jnp.max(errs)) < REL_ERR_AT_HALF
    assert float(maclaurin_rel_error(jnp.float32(-0.5))) > 0.029  # tight-ish


@given(st.floats(-0.5, 0.5))
@settings(max_examples=50, deadline=None)
def test_rel_error_property(x):
    assert float(maclaurin_rel_error(jnp.float32(x))) < REL_ERR_AT_HALF


# ---------------------------------------------------------------- Eq 3.7/3.8


def test_approx_matches_brute_force_expansion():
    """f_hat via (c, v, M) == directly substituting Eq 3.6 into the sum."""
    rng = np.random.default_rng(1)
    m = _random_model(rng)
    Z = jnp.asarray(rng.standard_normal((20, 7)).astype(np.float32) * 0.5)
    sv_sq = jnp.sum(m.X * m.X, axis=1)
    brute = []
    for z in Z:
        u = 2 * m.gamma * (m.X @ z)
        g_hat = jnp.sum(m.alpha_y * jnp.exp(-m.gamma * sv_sq) * (1 + u + 0.5 * u * u))
        brute.append(jnp.exp(-m.gamma * jnp.sum(z * z)) * g_hat + m.b)
    brute = jnp.stack(brute)
    am = approximate(m)
    np.testing.assert_allclose(
        np.asarray(approx_decision_function(am, Z)), np.asarray(brute), rtol=2e-4, atol=2e-5
    )


def test_approx_error_small_under_bound():
    """When Eq 3.11 holds, decision values are close and labels match."""
    rng = np.random.default_rng(2)
    X = rng.standard_normal((80, 6)).astype(np.float32)
    gm = float(gamma_max(jnp.asarray(X)))
    m = SVMModel(
        X=jnp.asarray(X),
        alpha_y=jnp.asarray(rng.standard_normal(80).astype(np.float32)),
        b=jnp.float32(0.1),
        gamma=jnp.float32(gm * 0.9),
    )
    Z = jnp.asarray(X[:40] * 0.9)
    am = approximate(m)
    f_hat, valid = approx_decision_function_checked(am, Z)
    assert bool(jnp.all(valid))
    f = decision_function(m, Z)
    # per-term rel err < 3.05% -> tight decision values in practice
    np.testing.assert_allclose(np.asarray(f_hat), np.asarray(f), rtol=0.1, atol=0.02)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_bound_implies_per_term_error_property(seed):
    """Property (the paper's §3.1 chain): Eq 3.11 -> |2g x^T z| < 1/2 ->
    every exp term's relative error < 3.05%."""
    rng = np.random.default_rng(seed)
    d = int(rng.integers(2, 10))
    X = jnp.asarray(rng.standard_normal((12, d)).astype(np.float32))
    z = jnp.asarray(rng.standard_normal((d,)).astype(np.float32))
    gamma = jnp.float32(float(rng.uniform(0.001, 0.3)))
    max_sq = jnp.max(jnp.sum(X * X, axis=1))
    if bool(bound_holds(max_sq, jnp.sum(z * z), gamma)):
        assert bool(exact_bound_holds(X, z, gamma))  # Cauchy-Schwarz chain
        u = 2 * gamma * (X @ z)
        assert float(jnp.max(maclaurin_rel_error(u))) < REL_ERR_AT_HALF


def test_gamma_max_consistency():
    """gamma < gamma_max(data) guarantees Eq 3.11 for any pair from data."""
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.standard_normal((64, 5)).astype(np.float32) * 2.0)
    gm = gamma_max(X)
    max_sq = jnp.max(jnp.sum(X * X, axis=1))
    assert bool(bound_holds(max_sq, max_sq, gm * 0.999))
    assert not bool(bound_holds(max_sq, max_sq, gm * 1.001))


def test_cauchy_schwarz_conservatism_grows_with_d():
    """§4.2: the bound is more conservative in higher d (random vectors)."""
    rng = np.random.default_rng(4)
    ratios = []
    for d in (4, 64, 512):
        X = jnp.asarray(rng.standard_normal((100, d)).astype(np.float32) / np.sqrt(d))
        Z = jnp.asarray(rng.standard_normal((100, d)).astype(np.float32) / np.sqrt(d))
        actual = max_abs_exponent(X, Z, jnp.float32(1.0))
        worst = 2 * 1.0 * jnp.sqrt(
            jnp.max(jnp.sum(X**2, 1)) * jnp.max(jnp.sum(Z**2, 1))
        )
        ratios.append(float(actual / worst))
    assert ratios[0] > ratios[1] > ratios[2]


# ---------------------------------------------------------------- model size


def test_compression_ratio_matches_paper_formula():
    """Approx model is O(d^2) scalars vs O(n_sv d) — Table 3 accounting."""
    rng = np.random.default_rng(5)
    m = _random_model(rng, n_sv=500, d=10)
    am = approximate(m)
    assert am.num_parameters() == 10 * 10 + 10 + 4
    assert m.num_parameters() == 500 * 10 + 500 + 2
    assert m.num_parameters() / am.num_parameters() > 40


# ---------------------------------------------------------------- §3.2 poly2


def test_poly2_collapse_is_exact():
    """The quadratic collapse of a poly-2 kernel model is EXACT (§3.2)."""
    rng = np.random.default_rng(6)
    X = jnp.asarray(rng.standard_normal((30, 5)).astype(np.float32))
    m = poly2.Poly2Model(
        X=X,
        alpha_y=jnp.asarray(rng.standard_normal(30).astype(np.float32)),
        b=jnp.float32(-0.2),
        gamma=jnp.float32(0.7),
        beta=jnp.float32(1.0),
    )
    Z = jnp.asarray(rng.standard_normal((25, 5)).astype(np.float32))
    direct = poly2.decision_function(m, Z)
    collapsed = approx_decision_function(poly2.collapse(m), Z)
    np.testing.assert_allclose(np.asarray(collapsed), np.asarray(direct), rtol=2e-4, atol=1e-4)


def test_rbf_approx_equals_scaled_poly2():
    """Eqs 3.13-3.16: approximated-RBF == exp(-g||z||^2) * poly2-with-folded-
    alphas, up to the documented 2x on second-order terms. We verify the
    construction identities c/v/M directly."""
    rng = np.random.default_rng(7)
    m = _random_model(rng, n_sv=20, d=4, gamma=0.3)
    am = approximate(m)
    sv_sq = jnp.sum(m.X * m.X, axis=1)
    folded = poly2.equivalent_poly2_alphas(m.alpha_y, sv_sq, m.gamma)
    pm = poly2.Poly2Model(
        X=m.X, alpha_y=folded, b=m.b, gamma=m.gamma, beta=jnp.float32(1.0)
    )
    pc = poly2.collapse(pm)
    np.testing.assert_allclose(np.asarray(pc.c), np.asarray(am.c), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(pc.v), np.asarray(am.v), rtol=1e-4, atol=1e-6)
    # paper: RBF approx second-order weight = 2 * poly2's (Eq 3.16)
    np.testing.assert_allclose(np.asarray(2.0 * pc.M), np.asarray(am.M), rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------- LOOPS path


def test_loops_equals_gemm_path():
    rng = np.random.default_rng(8)
    m = _random_model(rng)
    Z = jnp.asarray(rng.standard_normal((15, 7)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(decision_function_loops(m, Z)),
        np.asarray(decision_function(m, Z)),
        rtol=1e-4, atol=1e-5,
    )


def test_kernel_matrix_symmetry_and_diag():
    rng = np.random.default_rng(9)
    X = jnp.asarray(rng.standard_normal((20, 6)).astype(np.float32))
    K = rbf_kernel(X, X, jnp.float32(0.1))
    np.testing.assert_allclose(np.asarray(K), np.asarray(K.T), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(jnp.diag(K)), 1.0, rtol=1e-5)
